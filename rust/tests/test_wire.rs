//! The wire-protocol front end, end to end over real loopback sockets:
//! a `WireServer` on `127.0.0.1:0`, `WireClient`s driving it, and the
//! identity synthetic bundle as an exact oracle (logits == submitted
//! features, bit for bit — `f32` `Display` emits the shortest
//! round-tripping decimal, so even the JSON transport is lossless).
//!
//! Also pins the ingestion allocation contract with a counting global
//! allocator: after warm-up, `protocol::parse_request` performs zero
//! allocations per request line.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::Arc;
use std::time::Duration;

use analognets::coordinator::{Coordinator, MultiCoordinator, ServeConfig,
                              ShardConfig};
use analognets::datasets::synth::{self, SynthSpec};
use analognets::pcm::{T_1Y, T_C_SECONDS};
use analognets::server::protocol::{self, ReqBody, ReqScratch};
use analognets::server::{WireClient, WireConfig, WireServer};

// ---------------------------------------------------------------------------
// Counting allocator: every allocation on the current thread bumps a
// thread-local counter (thread-local so the parallel test harness cannot
// pollute the measurement; `try_with` so allocations during thread
// teardown, after TLS destruction, stay safe).
// ---------------------------------------------------------------------------

thread_local! {
    static TL_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn bump() {
    let _ = TL_ALLOCS.try_with(|c| c.set(c.get() + 1));
}

fn thread_allocs() -> u64 {
    TL_ALLOCS.try_with(Cell::get).unwrap_or(0)
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
                      -> *mut u8 {
        bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn request_parsing_is_allocation_free_after_warmup() {
    let feat = 16usize;
    // a line exercising every hot-path feature: an escaped id (forces the
    // scratch string decode instead of the borrow fast path), a
    // full-length tensor, both options
    let mut line = String::from("{\"id\": \"c0\\u002d17\", \"x\": [");
    for i in 0..feat {
        if i > 0 {
            line.push(',');
        }
        line.push_str("0.125");
    }
    line.push_str(r#"], "t_drift": 25.5, "adc_bits": 6}"#);

    let mut sc = ReqScratch::new(feat);
    for _ in 0..3 {
        protocol::parse_request(line.as_bytes(), feat, &mut sc).unwrap();
    }
    let before = thread_allocs();
    for _ in 0..100 {
        let p = protocol::parse_request(line.as_bytes(), feat, &mut sc).unwrap();
        assert_eq!(p.body, ReqBody::Features);
    }
    let after = thread_allocs();
    assert_eq!(after - before, 0,
               "request parsing allocated {} times over 100 warm requests",
               after - before);
    assert_eq!(sc.id, "c0-17");
    assert_eq!(sc.features.len(), feat);
}

// ---------------------------------------------------------------------------
// Loopback servers over synthetic bundles
// ---------------------------------------------------------------------------

const CLASSES: usize = 4;

/// Identity-model wire server: the response logits are exactly the request
/// features, so any cross-request mixup on the wire or in the batcher is
/// visible in the payload. Returns (server, coordinator, bundle dir, feat).
fn start_identity(tag: &str, tweak: impl FnOnce(&mut WireConfig))
                  -> (WireServer, Arc<Coordinator>, std::path::PathBuf, usize) {
    let spec = SynthSpec::identity_dense(&format!("ident_{tag}"), CLASSES);
    let dir = synth::write_bundle_tmp(&format!("wire_{tag}"), &spec).unwrap();
    let feat = spec.feat_len();
    let mut cfg = ServeConfig::new(&spec.vid, 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_wait = Duration::from_millis(2);
    let coord = Arc::new(Coordinator::start(cfg).unwrap());
    let store = analognets::runtime::ArtifactStore::open(&dir).unwrap();
    let ds = Arc::new(store.dataset(&spec.task).unwrap());
    let mut wcfg = WireConfig::default();
    tweak(&mut wcfg);
    let server = WireServer::start(coord.clone(), Some(ds), wcfg).unwrap();
    (server, coord, dir, feat)
}

/// Shut the server down, stop the coordinator, remove the bundle.
fn stop_all(mut server: WireServer, coord: Arc<Coordinator>,
            dir: &std::path::Path) {
    server.shutdown();
    drop(server); // releases the ConnShared -> Coordinator Arc
    match Arc::try_unwrap(coord) {
        Ok(c) => c.stop().unwrap(),
        Err(c) => c.request_stop(),
    }
    let _ = std::fs::remove_dir_all(dir);
}

fn features_for(c: usize, i: usize) -> Vec<f32> {
    (0..CLASSES)
        .map(|j| (c * 1000 + i) as f32 + 0.125 * j as f32)
        .collect()
}

#[test]
fn concurrent_clients_roundtrip_exact_logits_per_id() {
    let (server, coord, dir, feat) = start_identity("e2e", |_| {});
    assert_eq!(feat, CLASSES);
    let addr = server.local_addr();

    const NCLIENTS: usize = 3;
    const PER_CLIENT: usize = 20;
    let mut handles = Vec::new();
    for c in 0..NCLIENTS {
        handles.push(std::thread::spawn(move || {
            let mut cl = WireClient::connect(addr).unwrap();
            // pipeline the whole batch, then drain: replies must come back
            // in request order with each id's own payload
            for i in 0..PER_CLIENT {
                cl.send_x(&format!("t{c}-{i}"), &features_for(c, i), None, None)
                    .unwrap();
            }
            for i in 0..PER_CLIENT {
                let rep = cl.recv().unwrap();
                assert!(rep.ok, "t{c}-{i}: {:?}", rep.error);
                assert_eq!(rep.id, format!("t{c}-{i}"), "FIFO order broke");
                // identity model + shortest-round-trip floats: exact echo
                assert_eq!(rep.logits, features_for(c, i),
                           "request t{c}-{i} got foreign logits");
                assert_eq!(rep.pred as usize, CLASSES - 1);
                assert!(rep.latency_us >= 0.0);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }

    let m = coord.metrics.summary();
    assert_eq!(m.wire_requests, (NCLIENTS * PER_CLIENT) as u64);
    assert_eq!(m.wire_rejects, 0);
    assert_eq!(m.submit_rejects, 0);
    assert_eq!(m.completed, (NCLIENTS * PER_CLIENT) as u64);
    stop_all(server, coord, &dir);
}

#[test]
fn per_request_options_ride_the_wire() {
    // the analog tiny bundle with a frozen drift clock, exactly like
    // tests/test_infer_opts.rs — but through TCP
    let spec = SynthSpec::tiny("wire_opts");
    let dir = synth::write_bundle_tmp("wire_opts", &spec).unwrap();
    let feat = spec.feat_len();
    let mut cfg = ServeConfig::new(&spec.vid, 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_wait = Duration::from_millis(2);
    cfg.time_scale = 0.0;
    cfg.seed = 99;
    let coord = Arc::new(Coordinator::start(cfg).unwrap());
    let server =
        WireServer::start(coord.clone(), None, WireConfig::default()).unwrap();

    let mut cl = WireClient::connect(server.local_addr()).unwrap();
    let x = vec![0.9f32; feat];
    cl.send_x("aged", &x, Some(T_1Y), None).unwrap();
    cl.send_x("coarse", &x, None, Some(4)).unwrap();
    cl.send_x("plain", &x, None, None).unwrap();

    let aged = cl.recv().unwrap();
    let coarse = cl.recv().unwrap();
    let plain = cl.recv().unwrap();
    assert!(aged.ok && coarse.ok && plain.ok);
    assert_eq!(aged.sim_age_s, T_1Y, "t_drift rode the wire");
    assert_eq!(aged.adc_bits, 8);
    assert_eq!(coarse.sim_age_s, T_C_SECONDS);
    assert_eq!(coarse.adc_bits, 4, "adc_bits rode the wire");
    assert_eq!(plain.sim_age_s, T_C_SECONDS);
    assert_eq!(plain.adc_bits, 8);
    // the options changed the numbers, not just the labels
    assert_ne!(aged.logits, plain.logits,
               "a year of drift must change the served logits");
    assert_ne!(coarse.logits, plain.logits,
               "the 4-bit request must quantize differently");

    drop(cl);
    stop_all(server, coord, &dir);
}

#[test]
fn malformed_lines_answer_errors_and_never_kill_the_connection() {
    let (server, coord, dir, _feat) = start_identity("mal", |_| {});
    let mut cl = WireClient::connect(server.local_addr()).unwrap();

    // (line, expected error fragment, expected echoed id)
    let bad: &[(&str, &str, &str)] = &[
        ("this is not json", "expected", ""),
        (r#"{"id": "nox"}"#, "exactly one of", "nox"),
        (r#"{"id": "both", "x": [1, 2, 3, 4], "sample": 0}"#, "exactly one of",
         "both"),
        (r#"{"id": "short", "x": [1]}"#, "shorter than", "short"),
        (r#"{"id": "long", "x": [1, 2, 3, 4, 5]}"#, "longer than", "long"),
        (r#"{"id": "typo", "x": [1, 2, 3, 4], "adcbits": 4}"#, "unknown field",
         "typo"),
        (r#"{"x": [1, 2, 3, 4]}"#, "missing `id`", ""),
        (r#"{"id": "deep", "x": [1, 2, 3, 4], "meta": {"a": 1}}"#, "nested",
         "deep"),
    ];
    for (line, frag, want_id) in bad {
        cl.send_raw(line).unwrap();
        let rep = cl.recv().unwrap();
        assert!(!rep.ok, "accepted bad line: {line}");
        let err = rep.error.unwrap_or_default();
        assert!(err.contains(frag),
                "error {err:?} for {line:?} does not mention {frag:?}");
        assert_eq!(rep.id, *want_id, "id echo for {line:?}");
    }

    // blank and CRLF-terminated lines: no reply for the former, a normal
    // reply for the latter — and the connection is still alive
    cl.send_raw("").unwrap();
    cl.send_raw("{\"id\": \"crlf\", \"x\": [7, 8, 9, 10]}\r\n").unwrap();
    let rep = cl.recv().unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.id, "crlf");
    assert_eq!(rep.logits, vec![7.0, 8.0, 9.0, 10.0]);

    let m = coord.metrics.summary();
    assert_eq!(m.wire_rejects, bad.len() as u64);
    assert_eq!(m.wire_requests, bad.len() as u64 + 1,
               "blank lines are not requests");
    drop(cl);
    stop_all(server, coord, &dir);
}

#[test]
fn oversized_lines_reject_without_growing_the_buffer() {
    let (server, coord, dir, _feat) =
        start_identity("big", |w| w.max_line_bytes = 256);
    let mut cl = WireClient::connect(server.local_addr()).unwrap();

    // way past the cap: the server must answer (id unknowable -> null) and
    // keep the connection; the line buffer is capped so this cannot OOM
    let huge = format!(r#"{{"id": "{}", "x": [1, 2, 3, 4]}}"#,
                       "z".repeat(4096));
    cl.send_raw(&huge).unwrap();
    let rep = cl.recv().unwrap();
    assert!(!rep.ok);
    assert!(rep.error.unwrap_or_default().contains("max_line_bytes"));
    assert!(rep.id.is_empty(), "an oversized line cannot echo an id");

    // same connection, next line: served normally
    let rep = cl.roundtrip_x("after", &[1.0, 2.0, 3.0, 4.0], None, None)
        .unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, vec![1.0, 2.0, 3.0, 4.0]);

    let m = coord.metrics.summary();
    assert_eq!(m.wire_rejects, 1);
    assert_eq!(m.wire_requests, 2);
    drop(cl);
    stop_all(server, coord, &dir);
}

#[test]
fn connection_limit_refuses_politely_and_recovers() {
    let (server, coord, dir, _feat) =
        start_identity("cap", |w| w.max_conns = 1);
    let addr = server.local_addr();

    // the roundtrip pins connection 1 as accepted and active
    let mut c1 = WireClient::connect(addr).unwrap();
    let rep = c1.roundtrip_x("c1", &[1.0, 2.0, 3.0, 4.0], None, None).unwrap();
    assert!(rep.ok);

    // connection 2 is over the cap: one structured refusal line, then EOF
    let mut c2 = WireClient::connect(addr).unwrap();
    let rep = c2.recv().unwrap();
    assert!(!rep.ok);
    assert!(rep.error.unwrap_or_default().contains("connection limit"));
    assert!(c2.recv().is_err(), "refused connections are closed");

    // client 1 hangs up; once its reader exits, a new connection fits
    drop(c1);
    let t0 = std::time::Instant::now();
    while server.active_connections() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(5),
                "connection slot never freed");
        std::thread::sleep(Duration::from_millis(10));
    }
    let mut c3 = WireClient::connect(addr).unwrap();
    let rep = c3.roundtrip_x("c3", &[5.0, 6.0, 7.0, 8.0], None, None).unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, vec![5.0, 6.0, 7.0, 8.0]);

    drop(c2);
    drop(c3);
    stop_all(server, coord, &dir);
}

#[test]
fn sample_requests_serve_dataset_rows_and_check_bounds() {
    let (server, coord, dir, _feat) = start_identity("samp", |_| {});
    // the identity bundle's own test set is the oracle: logits == the row
    let store = analognets::runtime::ArtifactStore::open(&dir).unwrap();
    let ds = store.dataset("kws").unwrap();
    let row0: Vec<f32> = ds.batch(0, 1).to_vec();

    let mut cl = WireClient::connect(server.local_addr()).unwrap();
    cl.send_sample("s0", 0, None, None).unwrap();
    let rep = cl.recv().unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.id, "s0");
    assert_eq!(rep.logits, row0, "sample 0 must serve dataset row 0");

    cl.send_sample("oor", ds.len(), None, None).unwrap();
    let rep = cl.recv().unwrap();
    assert!(!rep.ok);
    assert!(rep.error.unwrap_or_default().contains("out of range"));
    assert_eq!(rep.id, "oor");
    drop(cl);

    // a second listener on the same coordinator, without a dataset:
    // `sample` requests answer a structured error instead
    let mut server2 =
        WireServer::start(coord.clone(), None, WireConfig::default()).unwrap();
    let mut cl2 = WireClient::connect(server2.local_addr()).unwrap();
    cl2.send_sample("nods", 0, None, None).unwrap();
    let rep = cl2.recv().unwrap();
    assert!(!rep.ok);
    assert!(rep.error.unwrap_or_default().contains("no dataset"));
    drop(cl2);
    server2.shutdown();
    drop(server2);

    stop_all(server, coord, &dir);
}

// ---------------------------------------------------------------------------
// Multi-model listeners: the `"model"` field
// ---------------------------------------------------------------------------

/// Two identity shards with *different* feature lengths (4 and 6) behind
/// one listener; the primary ("wake") model carries the dataset slot, the
/// "confirm" model deliberately has none. Returns (server, router, dir).
fn start_multi_identity(tag: &str)
                        -> (WireServer, Arc<MultiCoordinator>,
                            std::path::PathBuf) {
    let wake = SynthSpec::identity_dense(&format!("wake_{tag}"), CLASSES);
    let mut confirm =
        SynthSpec::identity_dense(&format!("confirm_{tag}"), CLASSES + 2);
    confirm.task = "vww".to_string();
    confirm.seed = 11;
    let dir = synth::write_multi_bundle_tmp(&format!("wire_{tag}"),
                                            &[wake.clone(), confirm.clone()])
        .unwrap();
    let mk = |vid: &str| {
        let mut cfg = ServeConfig::new(vid, 8);
        cfg.artifacts_dir = dir.clone();
        cfg.max_wait = Duration::from_millis(2);
        ShardConfig::new(vid, cfg)
    };
    let mc = Arc::new(
        MultiCoordinator::start(vec![mk(&wake.vid), mk(&confirm.vid)])
            .unwrap());
    let store = analognets::runtime::ArtifactStore::open(&dir).unwrap();
    let ds = Arc::new(store.dataset(&wake.task).unwrap());
    let server = WireServer::start_multi(mc.clone(), vec![Some(ds), None],
                                         WireConfig::default())
        .unwrap();
    (server, mc, dir)
}

fn stop_multi(mut server: WireServer, mc: Arc<MultiCoordinator>,
              dir: &std::path::Path) {
    server.shutdown();
    drop(server); // releases the ConnShared -> MultiCoordinator Arc
    match Arc::try_unwrap(mc) {
        Ok(c) => c.stop().unwrap(),
        Err(c) => c.request_stop(),
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn model_field_routes_on_a_multi_server() {
    let (server, mc, dir) = start_multi_identity("multi");
    let wake_id = mc.models()[0].model_id.clone();
    let confirm_id = mc.models()[1].model_id.clone();
    let mut cl = WireClient::connect(server.local_addr()).unwrap();

    // the wake -> confirm pipeline, explicitly addressed per line
    let wx = vec![1.0f32, 2.0, 3.0, 4.0];
    let cx = vec![9.0f32, 8.0, 7.0, 6.0, 5.0, 4.5];
    let rep = cl.roundtrip_x_model("w0", Some(&wake_id), &wx, None, None)
        .unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, wx, "wake logits are the exact identity echo");
    let rep = cl.roundtrip_x_model("c0", Some(&confirm_id), &cx, None, None)
        .unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, cx, "confirm logits are the exact identity echo");

    // no `"model"`: the primary serves, exactly like a single-model server
    let rep = cl.roundtrip_x("w1", &wx, None, None).unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, wx);

    // unknown model: structured error with the id echoed and the served
    // ids listed — and the connection stays alive
    let rep = cl.roundtrip_x_model("uk", Some("nope"), &wx, None, None)
        .unwrap();
    assert!(!rep.ok);
    let err = rep.error.unwrap_or_default();
    assert!(err.contains("unknown model `nope`"), "{err}");
    assert!(err.contains(wake_id.as_str()) && err.contains(confirm_id.as_str()),
            "the error must list the served models: {err}");
    assert_eq!(rep.id, "uk");

    // per-model exact length: a confirm-sized payload on the wake model
    let rep = cl.roundtrip_x_model("len", Some(&wake_id), &cx, None, None)
        .unwrap();
    assert!(!rep.ok);
    let err = rep.error.unwrap_or_default();
    assert!(err.contains("wants"), "{err}");
    assert_eq!(rep.id, "len");

    // beyond every served model's length: rejected at parse time (the
    // capacity bound is the largest served feature length)
    let over = vec![0.5f32; CLASSES + 3];
    let rep = cl.roundtrip_x_model("ov", Some(&confirm_id), &over, None, None)
        .unwrap();
    assert!(!rep.ok);
    assert!(rep.error.unwrap_or_default().contains("longer than"));

    // `sample` requests route through the per-model dataset slots: the
    // primary has one, the confirm model answers a structured error
    let store = analognets::runtime::ArtifactStore::open(&dir).unwrap();
    let row0: Vec<f32> = store.dataset("kws").unwrap().batch(0, 1).to_vec();
    cl.send_sample("s0", 0, None, None).unwrap();
    let rep = cl.recv().unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, row0, "primary sample serves dataset row 0");
    cl.send_raw(&format!(
        r#"{{"id": "nods", "model": "{confirm_id}", "sample": 0}}"#))
        .unwrap();
    let rep = cl.recv().unwrap();
    assert!(!rep.ok);
    assert!(rep.error.unwrap_or_default().contains("no dataset"));
    assert_eq!(rep.id, "nods");

    let m = mc.metrics.summary();
    assert_eq!(m.wire_requests, 8);
    assert_eq!(m.wire_rejects, 4);
    assert_eq!(m.per_model[wake_id.as_str()].completed, 3);
    assert_eq!(m.per_model[confirm_id.as_str()].completed, 1);

    drop(cl);
    stop_multi(server, mc, &dir);
}

#[test]
fn single_model_listener_rejects_the_model_field() {
    let (server, coord, dir, _feat) = start_identity("nomulti", |_| {});
    let mut cl = WireClient::connect(server.local_addr()).unwrap();

    let x = vec![1.0f32, 2.0, 3.0, 4.0];
    let rep = cl.roundtrip_x_model("m0", Some("ident_nomulti"), &x, None, None)
        .unwrap();
    assert!(!rep.ok, "a single-model listener must not silently ignore \
                      `model`");
    assert!(rep.error.unwrap_or_default().contains("not accepted here"));
    assert_eq!(rep.id, "m0");

    // the connection survives and unaddressed requests still serve
    let rep = cl.roundtrip_x("m1", &x, None, None).unwrap();
    assert!(rep.ok, "{:?}", rep.error);
    assert_eq!(rep.logits, x);

    let m = coord.metrics.summary();
    assert_eq!(m.wire_rejects, 1);
    assert_eq!(m.wire_requests, 2);
    drop(cl);
    stop_all(server, coord, &dir);
}
