//! Hermetic AnalogCim backend integration: synthetic artifact bundles
//! (datasets::synth — manifest + meta + ANWT weights + ANDS dataset, no
//! HLO) executed through the tile-faithful engine. Runs on a fresh checkout
//! with no `make artifacts`, no XLA library, and no `pjrt` feature.
//!
//! The acceptance invariants of the engine live here:
//! * degenerate physics (noise off, single-tile layers, unity GDC) is
//!   bit-identical to the native reference, and a >= 12-bit ADC keeps the
//!   argmax identical even across multi-tile geometries;
//! * drifted PCM execution is batch-invariant (the coordinator's dynamic
//!   batcher relies on that);
//! * `eval::drift_accuracy` and the serving `Coordinator` both run the
//!   tile-faithful physics end-to-end, including pre-aged serving via
//!   `ServeConfig::drift_time`.

use std::sync::Arc;
use std::time::Duration;

use analognets::backend::{AnalogCimBackend, BackendKind, HostTensor,
                          InferOpts, InferenceBackend};
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::crossbar::ArrayGeom;
use analognets::datasets::synth::{self, SynthSpec};
use analognets::eval::{drift_accuracy, drift_accuracy_on, DeployedModel,
                       EvalOpts};
use analognets::pcm::{PcmParams, T_25S, T_1Y};
use analognets::runtime::ArtifactStore;
use analognets::util::logits;
use analognets::util::rng::Rng;

/// Exact stored weights as host tensors + unity GDC (no PCM in the loop).
fn exact_weights(store: &ArtifactStore, vid: &str)
                 -> (Vec<HostTensor>, Vec<analognets::pcm::LayerGdc>) {
    let w = store.weights(vid).unwrap();
    let ws: Vec<HostTensor> = w.iter().map(HostTensor::from_tensor).collect();
    let unity = analognets::pcm::gdc::unity(ws.len());
    (ws, unity)
}

#[test]
fn exact_weights_single_tile_is_bit_identical_to_native() {
    let spec = SynthSpec::bench("ana_exact");
    let dir = synth::write_bundle_tmp("ana_exact", &spec).unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    let meta = store.meta(&spec.vid).unwrap();
    let (ws, unity) = exact_weights(&store, &spec.vid);
    let ds = store.dataset(&spec.task).unwrap();
    let n = 8;
    let xb = ds.padded_batch(0, n);

    let native = analognets::backend::create(BackendKind::Native, &store,
                                             &spec.vid, 12).unwrap();
    let analog = AnalogCimBackend::with_threads(meta, 12, 4);
    // every bench-bundle layer fits one AON tile
    assert_eq!(analog.tiles_total(), 3);
    let opts = InferOpts::default();
    let lo_n = native.run_batch(&xb, n, &ws, &unity, &opts).unwrap();
    let lo_a = analog.run_batch(&xb, n, &ws, &unity, &opts).unwrap();
    assert_eq!(lo_n, lo_a, "single-tile analog execution must reproduce the \
                            native bits");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn exact_weights_multi_tile_keeps_argmax_at_12_bits() {
    let spec = SynthSpec::bench("ana_tiles");
    let dir = synth::write_bundle_tmp("ana_tiles", &spec).unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    let meta = store.meta(&spec.vid).unwrap();
    let (ws, unity) = exact_weights(&store, &spec.vid);
    let ds = store.dataset(&spec.task).unwrap();
    let n = ds.len();
    let xb = ds.padded_batch(0, n);

    let native = analognets::backend::create(BackendKind::Native, &store,
                                             &spec.vid, 12).unwrap();
    // 32x8 tiles force K-splits on the 72x16 middle layer: per-tile ADC
    // quantization now happens *before* digital accumulation
    let geom = ArrayGeom::new(32, 8, 4).unwrap();
    let analog = AnalogCimBackend::with_geom(meta.clone(), 12, geom, 2);
    assert!(analog.tiles_total() > meta.layers.len(),
            "geometry must split at least one layer ({} tiles)",
            analog.tiles_total());

    let opts = InferOpts::default();
    let lo_n = native.run_batch(&xb, n, &ws, &unity, &opts).unwrap();
    let lo_a = analog.run_batch(&xb, n, &ws, &unity, &opts).unwrap();
    let classes = meta.num_classes;
    let pred_n = logits::predictions(&lo_n, classes);
    let pred_a = logits::predictions(&lo_a, classes);
    // per-tile quantization error is bounded by (#K-tiles) x half an ADC
    // step per layer; 0.02 is comfortably above that bound for this model
    // at 12 bits, so every sample with a larger native margin must keep
    // its argmax
    let mut checked = 0usize;
    for s in 0..n {
        let row = &lo_n[s * classes..(s + 1) * classes];
        let mut sorted: Vec<f32> = row.to_vec();
        sorted.sort_by(f32::total_cmp);
        let margin = sorted[classes - 1] - sorted[classes - 2];
        if margin > 0.02 {
            assert_eq!(pred_n[s], pred_a[s],
                       "sample {s}: 12-bit per-tile quantization flipped a \
                        {margin:.3}-margin argmax");
            checked += 1;
        }
    }
    assert!(checked > 0,
            "margin gate left no samples — synthetic task lost its margin");
    let _ = std::fs::remove_dir_all(&dir);
}

/// The layer-serial correctness invariant behind the coordinator's dynamic
/// batcher, on the tiled engine over drifted PCM weights: one
/// `run_batch(N)` is bit-identical to N sequential single-request runs.
#[test]
fn batched_analog_run_batch_is_bit_identical_to_sequential() {
    let spec = SynthSpec::bench("ana_batch");
    let dir = synth::write_bundle_tmp("ana_batch", &spec).unwrap();
    let store = ArtifactStore::open(&dir).unwrap();
    let meta = store.meta(&spec.vid).unwrap();
    let params = PcmParams::default();
    let mut rng = Rng::new(33);
    let dep = DeployedModel::program(&store, &spec.vid, &params, &mut rng)
        .unwrap();
    let (ws, alphas) = dep.read_at(3600.0, &params, &mut rng, true);

    let geom = ArrayGeom::new(32, 8, 4).unwrap();
    let be = AnalogCimBackend::with_geom(meta, 8, geom, 4);
    let ds = store.dataset(&spec.task).unwrap();
    let n = 6;
    let feat = ds.feat_len();
    let xb = ds.padded_batch(0, n);
    let opts = InferOpts::default();
    let batched = be.run_batch(&xb, n, &ws, &alphas, &opts).unwrap();
    assert_eq!(batched.len(), n * 2);
    for s in 0..n {
        let one = be
            .run_batch(&xb[s * feat..(s + 1) * feat], 1, &ws, &alphas, &opts)
            .unwrap();
        assert_eq!(one[..], batched[s * 2..(s + 1) * 2], "sample {s} diverged");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn analog_drift_sweep_runs_end_to_end() {
    let spec = SynthSpec::bench("ana_eval");
    let dir = synth::write_bundle_tmp("ana_eval", &spec).unwrap();
    let store = ArtifactStore::open(&dir).unwrap();

    // paper-default PCM params across the drift range
    let opts = EvalOpts {
        bits: 8,
        batch: 8,
        max_samples: 16,
        runs: 2,
        backend: BackendKind::AnalogCim,
        ..Default::default()
    };
    let accs = drift_accuracy(&store, &spec.vid, &[T_25S, T_1Y], &opts).unwrap();
    assert_eq!(accs.len(), 2);
    for per_time in &accs {
        assert_eq!(per_time.len(), opts.runs);
        for a in per_time {
            assert!((0.0..=1.0).contains(a), "accuracy out of range: {a}");
        }
    }

    // clean weights (ideal PCM, t = 25 s): the analog engine must agree
    // with the native reference run for run — same seed, same reads,
    // single-tile layers, so the accuracies are exactly equal
    let clean = EvalOpts {
        bits: 8,
        batch: 8,
        max_samples: 16,
        runs: 2,
        params: PcmParams::ideal(),
        backend: BackendKind::Native,
        t_drift: Some(T_25S),
        ..Default::default()
    };
    assert_eq!(clean.sweep_times(), vec![T_25S]);
    let acc_native =
        drift_accuracy(&store, &spec.vid, &clean.sweep_times(), &clean).unwrap();
    let clean_analog = EvalOpts { backend: BackendKind::AnalogCim, ..clean };
    let acc_analog = drift_accuracy(&store, &spec.vid,
                                    &clean_analog.sweep_times(),
                                    &clean_analog).unwrap();
    assert_eq!(acc_native, acc_analog);

    // the caller-constructed-backend hook with an explicit geometry agrees
    // with the factory path on the AON array
    let meta = store.meta(&spec.vid).unwrap();
    let be = AnalogCimBackend::with_geom(meta, 8, ArrayGeom::AON, 1);
    let acc_on = drift_accuracy_on(&be, &store, &spec.vid,
                                   &clean_analog.sweep_times(),
                                   &clean_analog).unwrap();
    assert_eq!(acc_on, acc_analog);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn analog_coordinator_serves_pre_aged_array() {
    let spec = SynthSpec::tiny("ana_serve");
    let dir = synth::write_bundle_tmp("ana_serve", &spec).unwrap();
    let mut cfg = ServeConfig::new(&spec.vid, 8)
        .with_backend(BackendKind::AnalogCim)
        .with_drift_time(86_400.0);
    assert_eq!(cfg.backend, BackendKind::AnalogCim);
    cfg.artifacts_dir = dir.clone();
    cfg.max_wait = Duration::from_millis(1);

    let coord = Arc::new(Coordinator::start(cfg).unwrap());
    let feat = coord.feat_len;
    let mut handles = Vec::new();
    for c in 0..3usize {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..6usize {
                let v = ((c * 6 + i) % 7) as f32 / 7.0;
                let resp = coord.infer(vec![v; feat]).unwrap();
                assert_eq!(resp.logits.len(), 2);
                assert!(resp.logits.iter().all(|l| l.is_finite()));
                // drift-aware serving: the array is already a day old
                assert!(resp.sim_age_s >= 86_400.0, "age {}", resp.sim_age_s);
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed as usize, 3 * 6);
    match Arc::try_unwrap(coord) {
        Ok(c) => c.stop().unwrap(),
        Err(_) => panic!("coordinator handle still shared"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn default_serve_config_starts_at_programming_age() {
    let cfg = ServeConfig::new("x", 8);
    assert!((cfg.drift_time - T_25S).abs() < 1e-9);
}
