//! Coordinator batching behaviour over the dynamic (layer-serial) drain,
//! hermetic via synthetic artifact bundles: batch assembly, the `max_batch`
//! cap, timeout flush, and request/response integrity (each request gets
//! exactly its own logits back — any FIFO mixup in batch assembly would
//! corrupt the payload of the identity model).

use std::sync::Arc;
use std::time::Duration;

use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::datasets::synth::{self, SynthSpec};

const CLASSES: usize = 4;

/// Identity-model coordinator: a single digital dense layer whose logits
/// are bit-identical to the submitted features.
fn identity_coord(tag: &str, max_batch: usize, max_wait_ms: u64)
                  -> (Coordinator, std::path::PathBuf) {
    let spec = SynthSpec::identity_dense("ident_batch", CLASSES);
    let dir = synth::write_bundle_tmp(tag, &spec).unwrap();
    let mut cfg = ServeConfig::new("ident_batch", 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_batch = max_batch;
    cfg.max_wait = Duration::from_millis(max_wait_ms);
    let coord = Coordinator::start(cfg).unwrap();
    (coord, dir)
}

fn features(i: usize) -> Vec<f32> {
    (0..CLASSES).map(|j| i as f32 + 0.125 * j as f32).collect()
}

#[test]
fn assembles_queue_into_capped_fifo_batches() {
    let (coord, dir) = identity_coord("assemble", 4, 300);
    // submit 10 requests inside one batching window: the dynamic plan must
    // produce ceil(10/4) = 3 launches ([4, 4, 2]) with zero padded slots
    let rxs: Vec<_> = (0..10).map(|i| coord.submit(features(i)).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        // identity model: the response carries exactly this request's
        // features — batch assembly preserved request identity
        assert_eq!(resp.logits, features(i), "request {i} got foreign logits");
        assert_eq!(resp.pred as usize, CLASSES - 1, "argmax is the last channel");
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 10);
    // all 10 usually land in one 300ms window (plan [4, 4, 2]); under CI
    // scheduling jitter they may split across windows, but every split
    // still needs at least ceil(10/4) capped launches and never pads
    assert!(m.launches >= 3 && m.launches <= 10, "{m}");
    assert_eq!(m.padded_slots, 0, "dynamic plans must never pad: {m}");
    assert!(m.mean_batch <= 4.0 + 1e-9, "cap exceeded: {m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn max_batch_cap_is_respected_under_flood() {
    let (coord, dir) = identity_coord("flood", 4, 5);
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(features(i)).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits, features(i), "request {i}");
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed as usize, n);
    // every launch carries at most max_batch requests, so there are at
    // least ceil(n / max_batch) launches, and never any padding
    assert!(m.launches as usize >= n / 4, "{m}");
    assert_eq!(m.padded_slots, 0, "{m}");
    assert!(m.mean_batch <= 4.0 + 1e-9, "cap exceeded: {m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn timeout_flushes_a_partial_batch() {
    let (coord, dir) = identity_coord("timeout", 32, 20);
    // a single request can never fill max_batch: only the max_wait timeout
    // can flush it
    let t0 = std::time::Instant::now();
    let resp = coord.infer(features(7)).unwrap();
    assert_eq!(resp.logits, features(7));
    assert!(t0.elapsed() < Duration::from_secs(5), "flush never happened");
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 1);
    assert_eq!(m.launches, 1);
    assert_eq!(m.padded_slots, 0, "{m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_clients_get_their_own_responses() {
    let (coord, dir) = identity_coord("integrity", 8, 1);
    let coord = Arc::new(coord);
    let mut handles = Vec::new();
    for c in 0..4usize {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let id = c * 1000 + i;
                let resp = coord.infer(features(id)).unwrap();
                assert_eq!(resp.logits, features(id),
                           "client {c} request {i} got foreign logits");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 100);
    assert_eq!(m.padded_slots, 0, "{m}");
    assert!(m.req_per_sec > 0.0, "{m}");
    let _ = std::fs::remove_dir_all(&dir);
}
