//! Coordinator batching behaviour over the dynamic (layer-serial) drain,
//! hermetic via synthetic artifact bundles: batch assembly, the `max_batch`
//! cap, timeout flush, and request/response integrity (each request gets
//! exactly its own logits back — any FIFO mixup in batch assembly would
//! corrupt the payload of the identity model).

use std::sync::Arc;
use std::time::Duration;

use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::datasets::synth::{self, SynthSpec};

const CLASSES: usize = 4;

/// Identity-model coordinator: a single digital dense layer whose logits
/// are bit-identical to the submitted features.
fn identity_coord(tag: &str, max_batch: usize, max_wait_ms: u64)
                  -> (Coordinator, std::path::PathBuf) {
    let spec = SynthSpec::identity_dense("ident_batch", CLASSES);
    let dir = synth::write_bundle_tmp(tag, &spec).unwrap();
    let mut cfg = ServeConfig::new("ident_batch", 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_batch = max_batch;
    cfg.max_wait = Duration::from_millis(max_wait_ms);
    let coord = Coordinator::start(cfg).unwrap();
    (coord, dir)
}

fn features(i: usize) -> Vec<f32> {
    (0..CLASSES).map(|j| i as f32 + 0.125 * j as f32).collect()
}

#[test]
fn assembles_queue_into_capped_fifo_batches() {
    let (coord, dir) = identity_coord("assemble", 4, 300);
    // submit 10 requests inside one batching window: the dynamic plan must
    // produce ceil(10/4) = 3 launches ([4, 4, 2]) with zero padded slots
    let rxs: Vec<_> = (0..10).map(|i| coord.submit(features(i)).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        // identity model: the response carries exactly this request's
        // features — batch assembly preserved request identity
        assert_eq!(resp.logits, features(i), "request {i} got foreign logits");
        assert_eq!(resp.pred as usize, CLASSES - 1, "argmax is the last channel");
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 10);
    // all 10 usually land in one 300ms window (plan [4, 4, 2]); under CI
    // scheduling jitter they may split across windows, but every split
    // still needs at least ceil(10/4) capped launches and never pads
    assert!(m.launches >= 3 && m.launches <= 10, "{m}");
    assert_eq!(m.padded_slots, 0, "dynamic plans must never pad: {m}");
    assert!(m.mean_batch <= 4.0 + 1e-9, "cap exceeded: {m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn max_batch_cap_is_respected_under_flood() {
    let (coord, dir) = identity_coord("flood", 4, 5);
    let n = 40;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(features(i)).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits, features(i), "request {i}");
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed as usize, n);
    // every launch carries at most max_batch requests, so there are at
    // least ceil(n / max_batch) launches, and never any padding
    assert!(m.launches as usize >= n / 4, "{m}");
    assert_eq!(m.padded_slots, 0, "{m}");
    assert!(m.mean_batch <= 4.0 + 1e-9, "cap exceeded: {m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn timeout_flushes_a_partial_batch() {
    let (coord, dir) = identity_coord("timeout", 32, 20);
    // a single request can never fill max_batch: only the max_wait timeout
    // can flush it
    let t0 = std::time::Instant::now();
    let resp = coord.infer(features(7)).unwrap();
    assert_eq!(resp.logits, features(7));
    assert!(t0.elapsed() < Duration::from_secs(5), "flush never happened");
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 1);
    assert_eq!(m.launches, 1);
    assert_eq!(m.padded_slots, 0, "{m}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slo_policy_caps_batches_below_fixed_config() {
    // the identity model is one dense [4 x 4] layer: mapped on the AON
    // array it models at exactly 1 MVM x t_cim(8) = 130 ns per inference,
    // so a 0.4 us SLO admits floor(400/130) = 3 inferences per launch —
    // strictly below the configured max_batch of 8. The policy is pure
    // arithmetic on the mapping, so this holds on any host.
    let spec = SynthSpec::identity_dense("ident_slo", CLASSES);
    let dir = synth::write_bundle_tmp("slo_cap", &spec).unwrap();
    let mut cfg = ServeConfig::new("ident_slo", 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(300);
    cfg.latency_slo_us = Some(0.4);
    let coord = Coordinator::start(cfg).unwrap();

    let n = 12;
    let rxs: Vec<_> = (0..n).map(|i| coord.submit(features(i)).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.logits, features(i), "request {i}");
        assert_eq!(resp.adc_bits, 8, "no bitwidth floor => bits stay pinned");
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed as usize, n);
    // the fixed config would plan ceil(12/8) = 2 launches; the SLO cap of
    // 3 forces at least ceil(12/3) = 4, however the windows split
    assert!(m.launches >= 4, "SLO cap ignored: {m}");
    assert!(m.mean_batch <= 3.0 + 1e-9, "modeled-latency cap exceeded: {m}");
    assert_eq!(m.padded_slots, 0, "{m}");
    // every launch was priced on the modeled schedule
    assert!(m.modeled_uj_per_inf > 0.0, "{m}");
    assert!(m.modeled_tops_w > 0.0, "{m}");
    assert!(m.to_json().contains("\"modeled\""), "{}", m.to_json());
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn slo_policy_requantizes_only_with_an_optin_floor() {
    use analognets::backend::InferOpts;
    // 100 ns SLO < the 130 ns single-inference model at 8 bits: a request
    // that opted into a bitwidth range is requantized down to the highest
    // bitwidth that fits (t_cim(7) = 65 ns), a pinned request serves at
    // its own bits at batch 1 (planning never rejects)
    let spec = SynthSpec::identity_dense("ident_requant", CLASSES);
    let dir = synth::write_bundle_tmp("slo_requant", &spec).unwrap();
    let mut cfg = ServeConfig::new("ident_requant", 8);
    cfg.artifacts_dir = dir.clone();
    cfg.max_batch = 8;
    cfg.max_wait = Duration::from_millis(5);
    cfg.latency_slo_us = Some(0.1);
    let coord = Coordinator::start(cfg).unwrap();

    let ranged = coord
        .infer_with(features(1), InferOpts::default().with_adc_bits_floor(4))
        .unwrap();
    assert!(ranged.adc_bits < 8 && ranged.adc_bits >= 4,
            "floor opt-in must trade bits for latency, got {}",
            ranged.adc_bits);
    // the identity layer is digital (exact at any bitwidth): requantizing
    // must not touch the payload
    assert_eq!(ranged.logits, features(1));

    let pinned = coord.infer(features(2)).unwrap();
    assert_eq!(pinned.adc_bits, 8,
               "accuracy is never traded without the opt-in");
    assert_eq!(pinned.logits, features(2));
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn concurrent_clients_get_their_own_responses() {
    let (coord, dir) = identity_coord("integrity", 8, 1);
    let coord = Arc::new(coord);
    let mut handles = Vec::new();
    for c in 0..4usize {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..25usize {
                let id = c * 1000 + i;
                let resp = coord.infer(features(id)).unwrap();
                assert_eq!(resp.logits, features(id),
                           "client {c} request {i} got foreign logits");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let m = coord.metrics.summary();
    assert_eq!(m.completed, 100);
    assert_eq!(m.padded_slots, 0, "{m}");
    assert!(m.req_per_sec > 0.0, "{m}");
    let _ = std::fs::remove_dir_all(&dir);
}
