//! Device-variability fault injection end-to-end.
//!
//! * **none-spec bit-identity** — a request carrying `FaultSpec::none()`
//!   serves bit-identically to an option-less request: the fault engine
//!   must be invisible until a non-zero magnitude is asked for.
//! * **seeded determinism** — the same spec (same seed) stamped onto two
//!   independently-built hermetic bundles yields bit-identical faulted
//!   conductance reads and bit-identical logits: fault patterns are a
//!   property of the spec, not of session history.
//! * **graceful degradation** — one coordinator serves faulted and clean
//!   cohorts side by side without worker death, rejects invalid specs at
//!   submit time, answers `probe_health`, and surfaces
//!   `degraded_responses` through `MetricsSummary::to_json`.

use std::time::Duration;

use analognets::backend::{AnalogCimBackend, BackendKind, InferOpts,
                          InferenceBackend};
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::datasets::synth::{self, SynthSpec};
use analognets::eval::DeployedModel;
use analognets::pcm::{FaultSpec, PcmParams};
use analognets::runtime::ArtifactStore;
use analognets::util::json;
use analognets::util::rng::Rng;

/// Analog-backend coordinator over a hermetic bundle with a frozen drift
/// clock, optionally under a deployment-default fault scenario.
fn start_coord(tag: &str, backend: BackendKind, faults: FaultSpec)
               -> (Coordinator, std::path::PathBuf, usize) {
    let spec = SynthSpec::tiny(tag);
    let dir = synth::write_bundle_tmp(tag, &spec).unwrap();
    let feat = spec.feat_len();
    let mut cfg = ServeConfig::new(&spec.vid, 8);
    cfg.artifacts_dir = dir.clone();
    cfg.backend = backend;
    cfg.max_wait = Duration::from_millis(40);
    cfg.time_scale = 0.0;
    cfg.seed = 99;
    cfg.faults = faults;
    (Coordinator::start(cfg).unwrap(), dir, feat)
}

#[test]
fn none_spec_requests_are_bit_identical_to_optionless() {
    let (coord, dir, feat) = start_coord("faults_none", BackendKind::AnalogCim,
                                         FaultSpec::none());
    let features = vec![0.7f32; feat];
    let plain = coord.infer(features.clone()).unwrap();
    let tagged = coord
        .infer_with(features, InferOpts::default().with_faults(FaultSpec::none()))
        .unwrap();
    assert_eq!(plain.logits, tagged.logits,
               "a none-spec must serve the exact clean path");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn seeded_faults_are_deterministic_across_sessions() {
    // two independently-written bundles of the same synthetic spec: the
    // weights are a function of the spec seed, so both sessions deploy the
    // same model with zero shared state
    let spec_a = SynthSpec::tiny("faults_det");
    let dir_a = synth::write_bundle_tmp("faults_det_a", &spec_a).unwrap();
    let dir_b = synth::write_bundle_tmp("faults_det_b", &spec_a).unwrap();
    let fspec = FaultSpec {
        stuck_min: 0.05,
        stuck_max: 0.05,
        g_sigma: 0.1,
        adc_offset_sigma: 0.02,
        adc_gain_sigma: 0.02,
        seed: 1234,
    };
    let params = PcmParams::default();
    let mut reads = Vec::new();
    let mut logits = Vec::new();
    for dir in [&dir_a, &dir_b] {
        let store = ArtifactStore::open(dir).unwrap();
        let mut rng = Rng::new(42);
        let mut dep =
            DeployedModel::program(&store, &spec_a.vid, &params, &mut rng)
                .unwrap();
        dep.apply_faults(&fspec);
        let mut read_rng = Rng::new(7);
        let (ws, alphas) = dep.read_at(3600.0, &params, &mut read_rng, true);
        let be = AnalogCimBackend::new(store.meta(&spec_a.vid).unwrap(), 8);
        let x = vec![0.6f32; spec_a.feat_len()];
        let lo = be
            .run_batch(&x, 1, &ws, &alphas,
                       &InferOpts::default().with_faults(fspec))
            .unwrap();
        reads.push((ws, alphas));
        logits.push(lo);
    }
    assert_eq!(reads[0], reads[1],
               "same seed must give bit-identical faulted conductance reads");
    assert_eq!(logits[0], logits[1],
               "same seed must give bit-identical faulted logits");
    let _ = std::fs::remove_dir_all(&dir_a);
    let _ = std::fs::remove_dir_all(&dir_b);
}

#[test]
fn coordinator_serves_mixed_fault_scenarios_gracefully() {
    // a deployment default heavy enough to visibly move the logits
    let deploy_spec = FaultSpec { stuck_max: 0.4, seed: 5, ..FaultSpec::none() };
    let (coord, dir, feat) = start_coord("faults_mixed",
                                         BackendKind::AnalogCim, deploy_spec);
    let features = vec![0.8f32; feat];

    // faulted (default), explicitly clean, and a third scenario, all
    // through one worker
    let faulted = coord.infer(features.clone()).unwrap();
    let clean = coord
        .infer_with(features.clone(),
                    InferOpts::default().with_faults(FaultSpec::none()))
        .unwrap();
    let other = coord
        .infer_with(features.clone(),
                    InferOpts::default().with_faults(FaultSpec {
                        stuck_min: 0.2,
                        seed: 11,
                        ..FaultSpec::none()
                    }))
        .unwrap();
    assert_ne!(faulted.logits, clean.logits,
               "40% stuck-at-Gmax must move the served logits");
    for r in [&faulted, &clean, &other] {
        assert!(r.logits.iter().all(|l| l.is_finite()));
    }

    // invalid specs die at submit, not in the worker
    let bad = FaultSpec { stuck_min: 2.0, ..FaultSpec::none() };
    assert!(coord
        .submit_with(features.clone(), InferOpts::default().with_faults(bad))
        .is_err());
    let m = coord.metrics.summary();
    assert_eq!(m.submit_rejects, 1, "{m}");

    // ... and the worker is demonstrably still alive afterwards
    let again = coord.infer(features.clone()).unwrap();
    assert_eq!(again.logits, faulted.logits,
               "frozen clock + cached read: the faulted cohort is stable");

    // the health probe answers on demand and its counters (plus the
    // degraded-response count) surface in the machine-readable metrics
    let hr = coord.probe_health().unwrap();
    assert!(hr.canary > 0 && hr.agree <= hr.canary, "{hr:?}");
    let m = coord.metrics.summary();
    assert!(m.health_probes >= 2,
            "startup probe + on-demand probe: {m}");
    assert_eq!(m.canary_total, m.health_probes * hr.canary as u64, "{m}");
    if hr.degraded {
        // every response after a degraded verdict counts
        let _ = coord.infer(features.clone()).unwrap();
        assert!(coord.metrics.summary().degraded_responses > 0);
    }
    let txt = json::write(&m.to_json());
    assert!(txt.contains("\"degraded_responses\":"), "{txt}");
    assert!(txt.contains("\"health_probes\":"), "{txt}");
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn backend_gates_reject_unservable_specs_at_submit() {
    // ADC gain/offset errors only execute on the tile-faithful engine: a
    // native-backend session must reject the spec at submit time
    let (coord, dir, feat) = start_coord("faults_native", BackendKind::Native,
                                         FaultSpec::none());
    let adc_spec = FaultSpec { adc_gain_sigma: 0.1, ..FaultSpec::none() };
    assert!(coord
        .submit_with(vec![0.5f32; feat],
                     InferOpts::default().with_faults(adc_spec))
        .is_err());
    // weight-side faults are engine-independent and serve fine natively
    let weighty = FaultSpec { stuck_min: 0.1, seed: 3, ..FaultSpec::none() };
    let r = coord
        .infer_with(vec![0.5f32; feat],
                    InferOpts::default().with_faults(weighty))
        .unwrap();
    assert!(r.logits.iter().all(|l| l.is_finite()));
    coord.stop().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
