//! Visual-wake-words camera scenario: an always-on VWW model wakes a
//! (simulated) host SoC when a person enters the frame.
//!
//! Demonstrates the second AnalogNets workload end to end, plus the
//! wake-event behaviour the paper's Figure 1 motivates: the coordinator
//! stays in its low-power loop and only "wakes" the host on a positive.
//!
//!   make artifacts && cargo run --release --example vww_camera

use analognets::backend::BackendKind;
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::runtime::ArtifactStore;
use analognets::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let vid = args.opt_or("vid", "vww_full_e10_8b");
    let frames = args.opt_usize("frames", 300);
    let backend = BackendKind::from_args(&args)?;

    let store = ArtifactStore::open_default()?;
    let ds = store.dataset("vww")?;
    drop(store);

    let mut cfg = ServeConfig::new(&vid, 8).with_backend(backend);
    cfg.time_scale = 1e4;
    cfg.max_wait = std::time::Duration::from_millis(1);
    let coord = Coordinator::start(cfg)?;

    let feat = ds.feat_len();
    let (mut tp, mut fp, mut tn, mut fn_) = (0usize, 0usize, 0usize, 0usize);
    let mut wakes = 0usize;
    for i in 0..frames {
        let s = (i * 7) % ds.len(); // stride the set so classes interleave
        let resp = coord.infer(ds.x[s * feat..(s + 1) * feat].to_vec())?;
        let person = ds.y[s] == 1;
        let pred = resp.pred == 1;
        match (person, pred) {
            (true, true) => { tp += 1; wakes += 1; }
            (false, true) => { fp += 1; wakes += 1; }
            (true, false) => fn_ += 1,
            (false, false) => tn += 1,
        }
    }
    let m = coord.metrics.summary();
    println!("== VWW camera wake-word run ==");
    println!("frames {frames}: TP {tp} FP {fp} TN {tn} FN {fn_}");
    println!("accuracy  : {:.2}%", 100.0 * (tp + tn) as f64 / frames as f64);
    println!("wake rate : {:.1}% of frames", 100.0 * wakes as f64 / frames as f64);
    println!("precision : {:.2}%  recall {:.2}%",
             100.0 * tp as f64 / (tp + fp).max(1) as f64,
             100.0 * tp as f64 / (tp + fn_).max(1) as f64);
    println!("latency   : p50 {:.0}us p99 {:.0}us", m.p50_us, m.p99_us);
    println!("sim energy: {:.2} uJ/inf (paper: 15.6 uJ/inf @8b)",
             m.sim_uj_per_inf);
    coord.stop()?;
    println!("vww_camera OK");
    Ok(())
}
