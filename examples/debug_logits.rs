//! Debug: feed the exported graph the *clean* weights directly (no PCM) and
//! print the first logits row, to compare against the python reference.

use analognets::nn::expand_dw_dense;
use analognets::runtime::{ArtifactStore, HostTensor};

fn main() -> anyhow::Result<()> {
    let store = ArtifactStore::open_default()?;
    let vid = std::env::args().nth(1).unwrap_or("kws_full_e10_8b".into());
    let meta = store.meta(&vid)?;
    let tensors = store.weights(&vid)?;
    let ds = store.dataset("kws")?;
    let batch = 128;
    let exe = store.executable(&vid, meta.trained_adc_bits.unwrap_or(8), batch)?;
    let (ih, iw, ic) = meta.input_hwc;

    let mut inputs = Vec::new();
    inputs.push(HostTensor::new(vec![batch, ih, iw, ic],
                                ds.padded_batch(0, batch)));
    for (t, lm) in tensors.iter().zip(meta.layers.iter()) {
        let t = if lm.kind == analognets::nn::LayerKind::Dw3x3 && lm.analog {
            expand_dw_dense(t)
        } else {
            t.clone()
        };
        inputs.push(HostTensor::new(t.shape.clone(), t.data.clone()));
    }
    inputs.push(HostTensor::new(vec![meta.layers.len()],
                                vec![1.0; meta.layers.len()]));
    let logits = exe.run(&inputs)?;
    println!("logits row0: {:?}", &logits[..meta.num_classes]);
    let mut correct = 0;
    for (i, row) in logits.chunks_exact(meta.num_classes).enumerate() {
        let pred = row.iter().enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0 as u32;
        correct += (pred == ds.y[i]) as usize;
    }
    println!("clean-weight HLO acc: {}/{batch}", correct);
    println!("x[0][..8] = {:?}", &ds.x[..8]);
    println!("y[..8] = {:?}", &ds.y[..8]);
    Ok(())
}
