//! Debug: feed a backend the *clean* trained weights directly (no PCM
//! noise, no drift) and print the first logits row, to compare against the
//! python reference. `--backend pjrt` runs the exported graph instead of
//! the native simulator (requires `--features pjrt`).

use analognets::backend::{self, BackendKind, HostTensor, InferenceBackend};
use analognets::nn::expand_dw_dense;
use analognets::runtime::ArtifactStore;
use analognets::util::cli::Args;
use analognets::util::logits;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = ArtifactStore::open_default()?;
    let vid = args
        .positional
        .first()
        .cloned()
        .unwrap_or_else(|| args.opt_or("vid", "kws_full_e10_8b"));
    let kind = BackendKind::from_args(&args)?;
    let meta = store.meta(&vid)?;
    let tensors = store.weights(&vid)?;
    let ds = store.dataset("kws")?;
    let batch = 128;
    let be = backend::create(kind, &store, &vid,
                             meta.trained_adc_bits.unwrap_or(8))?;

    // clean weights in graph shape, unit GDC: the noise-free reference
    let ws: Vec<HostTensor> = tensors
        .iter()
        .zip(meta.layers.iter())
        .map(|(t, lm)| {
            if lm.kind == analognets::nn::LayerKind::Dw3x3 && lm.analog {
                HostTensor::from_tensor(&expand_dw_dense(t))
            } else {
                HostTensor::from_tensor(t)
            }
        })
        .collect();
    let gdc = vec![1.0f32; ws.len()];

    let out = be.run_batch(&ds.padded_batch(0, batch), batch, &ws, &gdc,
                           &analognets::backend::InferOpts::default())?;
    println!("[{}] logits row0: {:?}", be.name(), &out[..meta.num_classes]);
    let n = batch.min(ds.len());
    let correct = logits::count_correct(&out, meta.num_classes, &ds.y[..n]);
    println!("clean-weight {} acc: {correct}/{n}", be.name());
    println!("x[0][..8] = {:?}", &ds.x[..8]);
    println!("y[..8] = {:?}", &ds.y[..8]);
    Ok(())
}
