//! Perf probe (no artifacts needed): measures the L3 substrate hot paths —
//! the PCM weight-refresh loop before/after optimization, and the native
//! GEMM. Used for the EXPERIMENTS.md §Perf iteration log.
//!
//!   cargo run --release --example perf_probe

use analognets::pcm::{device, PcmParams, ProgrammedWeights};
use analognets::simulator::gemm;
use analognets::util::rng::Rng;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(5);
    // AnalogNet-KWS-sized deployment: 307k weights
    let (rows, cols) = (1008usize, 305usize);
    let n_w = rows * cols; // ~307k: AnalogNet-KWS-sized deployment
    let w: Vec<f32> = (0..n_w).map(|_| rng.gauss(0.0, 0.2) as f32).collect();
    let p = PcmParams::default();
    let prog = ProgrammedWeights::program(&w, rows, cols, 0.0, &p, &mut rng);

    // BEFORE: the naive per-device path (device::read with powf/ln/sqrt
    // per device) — kept in device.rs as the reference implementation
    let t0 = Instant::now();
    let mut acc = 0f64;
    for rep in 0..3 {
        let t = 86_400.0;
        for i in 0..n_w {
            acc += device::read(prog.gp_pos[i] as f64, prog.gt_pos[i] as f64,
                                prog.nu_pos[i] as f64, t, &p, &mut rng);
            acc += device::read(prog.gp_neg[i] as f64, prog.gt_neg[i] as f64,
                                prog.nu_neg[i] as f64, t, &p, &mut rng);
        }
        let _ = rep;
    }
    let naive_ms = t0.elapsed().as_secs_f64() * 1e3 / 3.0;
    println!("PCM refresh naive (per-device read): {naive_ms:.1} ms ({acc:.1})");

    // AFTER: the hoisted/cached read_weights hot path
    let t0 = Instant::now();
    for _ in 0..3 {
        let r = prog.read_weights(86_400.0, &p, &mut rng);
        std::hint::black_box(&r);
    }
    let fast_ms = t0.elapsed().as_secs_f64() * 1e3 / 3.0;
    println!("PCM refresh optimized (read_weights): {fast_ms:.1} ms \
              ({:.2}x)", naive_ms / fast_ms);

    // native GEMM roofline on this box
    let (m, k, n) = (2048usize, 576usize, 128usize);
    let a: Vec<f32> = (0..m * k).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 1.0) as f32).collect();
    let t0 = Instant::now();
    let c = gemm::gemm(&a, &b, m, k, n);
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    std::hint::black_box(&c);
    println!("native GEMM {m}x{k}x{n}: {ms:.1} ms = {:.2} GFLOP/s",
             2.0 * (m * k * n) as f64 / ms / 1e6);
}
