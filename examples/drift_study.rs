//! Drift study: how KWS accuracy decays over a simulated year, with and
//! without global drift compensation, and how the reprogramming policy
//! resets the decay (the deployment decision the paper's Figure 7 informs).
//!
//!   make artifacts && cargo run --release --example drift_study

use analognets::backend::BackendKind;
use analognets::eval::{drift_accuracy, EvalOpts};
use analognets::pcm::{PcmParams, FIG7_TIMES};
use analognets::runtime::ArtifactStore;
use analognets::util::cli::Args;
use analognets::util::stats;
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let vid = args.opt_or("vid", "kws_full_e10_8b");
    let runs = args.opt_usize("runs", 3);
    let samples = args.opt_usize("samples", 256);
    let backend = BackendKind::from_args(&args)?;
    let store = ArtifactStore::open_default()?;
    let times: Vec<f64> = FIG7_TIMES.iter().map(|(_, t)| *t).collect();

    let mut t = Table::new(
        &format!("drift study: {vid} (mean acc % over {runs} runs)"),
        &["configuration", "25s", "1h", "1d", "1mo", "1yr"],
    );

    for (label, use_gdc, read_noise) in [
        ("GDC on, read noise on (paper)", true, true),
        ("GDC off", false, true),
        ("read noise off (drift only)", true, false),
    ] {
        let opts = EvalOpts {
            bits: 8,
            runs,
            max_samples: samples,
            use_gdc,
            params: PcmParams { read_noise, ..Default::default() },
            backend,
            ..Default::default()
        };
        let accs = drift_accuracy(&store, &vid, &times, &opts)?;
        let mut cells = vec![label.to_string()];
        for a in &accs {
            let (m, _) = stats::acc_summary(a);
            cells.push(format!("{m:.1}"));
        }
        t.row(&cells);
        eprintln!("[drift_study] done: {label}");
    }

    // reprogramming: a fresh programming at 1 month restores 25s-level acc
    let opts = EvalOpts { bits: 8, runs, max_samples: samples, backend,
                          ..Default::default() };
    let fresh = drift_accuracy(&store, &vid, &[25.0], &opts)?;
    let (m_fresh, _) = stats::acc_summary(&fresh[0]);
    t.row(&["after reprogramming (any age)".into(), format!("{m_fresh:.1}"),
            "=".into(), "=".into(), "=".into(), "=".into()]);
    t.print();
    println!("conclusion: GDC recovers the global drift component; the \
              device-to-device nu spread remains and grows with log(t); \
              reprogramming fully resets the clock.");
    Ok(())
}
