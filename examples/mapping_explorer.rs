//! Mapping explorer: interactive view of how each model lands on the CiM
//! array, and what utilization/performance different array geometries give —
//! the co-design loop the paper's Future Work section suggests.
//!
//!   cargo run --release --example mapping_explorer [-- --vid <vid>]

use analognets::crossbar::ArrayGeom;
use analognets::mapping::{layout, map_model, split_map_model};
use analognets::runtime::ArtifactStore;
use analognets::timing::{model_perf, EnergyModel};
use analognets::util::cli::Args;
use analognets::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let store = ArtifactStore::open_default()?;
    let em = EnergyModel::default();

    let vids: Vec<String> = match args.opt("vid") {
        Some(v) => vec![v.to_string()],
        None => vec!["kws_full_e10_8b".into(), "vww_full_e10_8b".into(),
                     "micro_noise_e10".into()],
    };

    for vid in &vids {
        let meta = store.meta(vid)?;
        println!("\n################ {vid} ################");
        let m = map_model(&meta, ArrayGeom::AON)?;
        print!("{}", layout::ascii_map(&m, 64, 20));

        let mut t = Table::new(
            &format!("{vid}: geometry sweep (8-bit)"),
            &["geometry", "fits whole?", "eff util %", "inf/s"],
        );
        for (label, rows, cols) in [("1024x512", 1024, 512),
                                    ("512x512", 512, 512),
                                    ("2048x256", 2048, 256),
                                    ("256x256", 256, 256),
                                    ("128x128", 128, 128),
                                    ("64x64", 64, 64)] {
            let geom = ArrayGeom::new(rows, cols, 4)?;
            match map_model(&meta, geom) {
                Ok(mm) => {
                    let p = model_perf(&mm, 8, &em);
                    t.row(&[label.into(), "yes".into(),
                            format!("{:.1}", 100.0 * mm.effective_utilization()),
                            format!("{:.0}", p.inf_per_sec)]);
                }
                Err(_) => {
                    let s = split_map_model(&meta, geom);
                    let r = analognets::timing::perf::split_inference_rate(&s, 8, &em);
                    t.row(&[label.into(),
                            format!("no ({} tiles)", s.alloc_tiles()),
                            format!("{:.1}", 100.0 * s.effective_utilization()),
                            format!("{r:.0}")]);
                }
            }
        }
        t.print();
    }
    Ok(())
}
