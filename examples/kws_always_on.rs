//! END-TO-END DRIVER (EXPERIMENTS.md records this run): an always-on
//! keyword-spotting deployment on the AON-CiM accelerator.
//!
//! All layers compose here: the synthetic microphone stream feeds the Rust
//! coordinator (L3), which batches requests, manages the PCM array state
//! (drift clock accelerated 100,000x, periodic GDC recalibration), and
//! executes the AOT-exported JAX+Pallas graph (L2+L1) via PJRT.  Reports
//! streaming accuracy, request latency, simulated accelerator energy, and
//! the accuracy trajectory as the simulated device ages.
//!
//!   make artifacts && cargo run --release --example kws_always_on

use analognets::backend::BackendKind;
use analognets::coordinator::{Coordinator, ServeConfig};
use analognets::runtime::ArtifactStore;
use analognets::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let vid = args.opt_or("vid", "kws_full_e10_8b");
    let requests = args.opt_usize("requests", 2000);
    let time_scale = args.opt_f64("time-scale", 1e5);
    let backend = BackendKind::from_args(&args)?;

    let store = ArtifactStore::open_default()?;
    let meta = store.meta(&vid)?;
    let ds = store.dataset("kws")?;
    println!("== always-on KWS on AON-CiM ==");
    println!("model {} ({} params, fp ref {:.2}%), drift clock {time_scale}x, \
              `{backend}` backend",
             meta.model, meta.param_count(), 100.0 * meta.fp_test_acc);
    drop(store);

    let mut cfg = ServeConfig::new(&vid, 8).with_backend(backend);
    cfg.time_scale = time_scale;          // 1 wall-second = ~1.2 sim-days
    cfg.refresh_every_s = 3600.0;         // refresh weights hourly (sim)
    cfg.max_wait = std::time::Duration::from_millis(1);
    let coord = Coordinator::start(cfg)?;

    let feat = ds.feat_len();
    let mut correct = 0usize;
    let mut window_correct = 0usize;
    let t0 = std::time::Instant::now();
    let window = (requests / 8).max(1);
    for i in 0..requests {
        let s = i % ds.len();
        let resp = coord.infer(ds.x[s * feat..(s + 1) * feat].to_vec())?;
        let ok = resp.pred == ds.y[s];
        correct += ok as usize;
        window_correct += ok as usize;
        if (i + 1) % window == 0 {
            println!("  [age {:>9.0} sim-s] window acc {:>6.2}%  (req {}..{})",
                     resp.sim_age_s,
                     100.0 * window_correct as f64 / window as f64,
                     i + 1 - window, i + 1);
            window_correct = 0;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.metrics.summary();
    println!("-----------------------------------------------");
    println!("streaming accuracy : {:.2}% over {requests} requests",
             100.0 * correct as f64 / requests as f64);
    println!("wall throughput    : {:.0} req/s ({wall:.1}s total)",
             requests as f64 / wall);
    println!("latency            : p50 {:.0}us p99 {:.0}us", m.p50_us, m.p99_us);
    println!("launches           : {} ({} padded slots)", m.launches,
             m.padded_slots);
    println!("weight refreshes   : {}", m.weight_refreshes);
    println!("sim accel energy   : {:.2} uJ/inf (paper: 8.22 uJ/inf @8b)",
             m.sim_uj_per_inf);
    coord.stop()?;
    println!("kws_always_on OK");
    Ok(())
}
