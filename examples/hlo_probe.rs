//! Debug probe: run an arbitrary exported HLO with all-ones inputs of the
//! shapes given in a JSON spec, print output stats.
//!   hlo_probe /tmp/bisect_specs.json /tmp/bisect_<name>.hlo.txt <name>

use analognets::runtime::{HostTensor, Runtime};
use analognets::util::json;

fn main() -> anyhow::Result<()> {
    let mut args = std::env::args().skip(1);
    let specs = json::parse_file(std::path::Path::new(&args.next().unwrap()))?;
    let hlo = args.next().unwrap();
    let name = args.next().unwrap();
    let shapes = specs.req(&name)?.as_arr()?;
    let rt = Runtime::cpu()?;
    let exe = rt.load_hlo(std::path::Path::new(&hlo))?;
    let mut inputs = Vec::new();
    for s in shapes {
        let dims = s.usizes()?;
        let n: usize = dims.iter().product();
        // deterministic non-trivial data
        let data: Vec<f32> = (0..n).map(|i| ((i % 13) as f32 - 6.0) / 7.0).collect();
        inputs.push(HostTensor::new(dims, data));
    }
    let out = exe.run(&inputs)?;
    let sum: f64 = out.iter().map(|x| *x as f64).sum();
    let nz = out.iter().filter(|x| x.abs() > 1e-9).count();
    println!("{name}: len={} sum={sum:.4} nonzero={nz} head={:?}",
             out.len(), &out[..out.len().min(6)]);
    Ok(())
}
