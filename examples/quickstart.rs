//! Quickstart: one inference through the unified `InferenceBackend` API.
//!
//! Hermetic on purpose — it builds a tiny model description inline and runs
//! a batch on the `native` backend, so it works on a fresh checkout with no
//! artifacts and no XLA library:
//!
//!   cargo run --release --example quickstart
//!
//! The exact same `run_batch` call executes the exported HLO graphs when
//! the crate is built with `--features pjrt` (see `--backend pjrt` on the
//! CLI and the serving examples).

use analognets::backend::{HostTensor, InferOpts, InferenceBackend,
                          NativeBackend};
use analognets::nn::ModelMeta;
use analognets::util::json;
use analognets::util::logits;
use analognets::util::rng::Rng;

const TINY: &str = r#"{
  "model": "quickstart_kws", "variant": "demo", "input_hwc": [4, 4, 1],
  "num_classes": 3, "eta": 0.0, "fp_test_acc": 1.0, "trained_adc_bits": 8,
  "layers": [
    {"name": "c0", "kind": "conv3x3", "in_ch": 1, "out_ch": 4,
     "stride": [1, 1], "relu": true, "analog": true,
     "in_h": 4, "in_w": 4, "out_h": 4, "out_w": 4,
     "k_gemm": 9, "weight_shape": [9, 4], "graph_weight_shape": [9, 4],
     "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
     "dig_scale": [1, 1, 1, 1], "dig_bias": [0, 0, 0, 0]},
    {"name": "fc", "kind": "dense", "in_ch": 4, "out_ch": 3,
     "stride": [1, 1], "relu": false, "analog": true,
     "in_h": 4, "in_w": 4, "out_h": 1, "out_w": 1,
     "k_gemm": 4, "weight_shape": [4, 3], "graph_weight_shape": [4, 3],
     "w_scale": 1.0, "w_max": 1.0, "r_dac": 8.0, "r_adc": 8.0,
     "dig_scale": [1, 1, 1], "dig_bias": [0, 0, 0]}
  ],
  "hlo": {}
}"#;

fn main() -> anyhow::Result<()> {
    let meta = ModelMeta::from_json(&json::parse(TINY)?)?;
    let classes = meta.num_classes;
    let backend = NativeBackend::new(meta, 8);
    println!("backend `{}` at {} bits, input {:?}, {} classes",
             backend.name(), backend.bits(), backend.input_hwc(), classes);

    // random "trained" weights for the two layers, in graph shape
    let mut rng = Rng::new(42);
    let w: Vec<HostTensor> = backend
        .meta()
        .layers
        .iter()
        .map(|lm| {
            let n: usize = lm.graph_weight_shape.iter().product();
            let data: Vec<f32> =
                (0..n).map(|_| rng.gauss(0.0, 0.3) as f32).collect();
            HostTensor::new(lm.graph_weight_shape.clone(), data)
        })
        .collect();
    // fresh deployment: no drift yet, so all GDC factors are 1.0 (see the
    // drift_study example for the full PCM program/read/compensate flow)
    let gdc = vec![1.0f32; w.len()];

    let batch = 2;
    let x: Vec<f32> = (0..batch * backend.feat_len())
        .map(|i| ((i % 7) as f32) / 7.0)
        .collect();
    let opts = InferOpts::default();
    let out = backend.run_batch(&x, batch, &w, &gdc, &opts)?;
    println!("logits [{batch}x{classes}]: {out:?}");
    println!("preds: {:?}", logits::predictions(&out, classes));

    // determinism check: the simulator is pure
    let out2 = backend.run_batch(&x, batch, &w, &gdc, &opts)?;
    anyhow::ensure!(out == out2, "native backend must be deterministic");

    // per-request options: the same deployment served at a 4-bit ADC
    // (paper Table 2) — one argument, no second backend
    let out4 =
        backend.run_batch(&x, batch, &w, &gdc, &opts.with_adc_bits(4))?;
    println!("4-bit logits row0: {:?}", &out4[..classes]);
    println!("quickstart OK");
    Ok(())
}
