//! Quickstart: load the standalone L1 CiM kernel (pallas -> HLO) and run a
//! single analog matrix-vector product through the PJRT runtime.
//!
//!   make artifacts && cargo run --release --example quickstart

use analognets::nn::manifest::artifacts_dir;
use analognets::quant;
use analognets::runtime::{HostTensor, Runtime};
use analognets::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let path = artifacts_dir().join("cim_mvm.hlo.txt");
    anyhow::ensure!(path.exists(), "run `make artifacts` first ({} missing)",
                    path.display());

    let rt = Runtime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load_hlo(&path)?;
    println!("compiled {}", exe.name);

    // the exported demo kernel is x[256,432] @ w[432,128] with r_dac=1,
    // r_adc=8 at 9/8-bit DAC/ADC — one AnalogNet-KWS-sized layer
    let (m, k, n) = (256usize, 432usize, 128usize);
    let mut rng = Rng::new(42);
    let x: Vec<f32> = (0..m * k).map(|_| rng.range(-1.0, 1.0) as f32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.gauss(0.0, 0.05) as f32).collect();

    let out = exe.run(&[
        HostTensor::new(vec![m, k], x.clone()),
        HostTensor::new(vec![k, n], w.clone()),
    ])?;
    println!("ran CiM MVM: [{m}x{k}] @ [{k}x{n}] -> {} outputs", out.len());

    // cross-check one output against the quantizer contract
    let mut acc = 0f64;
    for kk in 0..k {
        acc += quant::fake_quant(x[kk], 1.0, 9) as f64 * w[kk * n] as f64;
    }
    let want = quant::fake_quant(acc as f32, 8.0, 8);
    println!("out[0,0] = {:.5} (host re-computation: {want:.5})", out[0]);
    anyhow::ensure!((out[0] - want).abs() <= 8.0 / 127.0 + 1e-5,
                    "kernel result mismatch");
    println!("quickstart OK");
    Ok(())
}
